"""Fail on broken intra-repo markdown links (the docs CI gate).

    python docs/check_links.py [files...]

Defaults to README.md, DESIGN.md, and docs/*.md. Checks every
``[text](target)`` link whose target is not an external URL:

  * relative file targets must exist on disk (resolved against the
    containing file's directory);
  * ``#anchor`` fragments (same-file or on a ``.md`` target) must match
    a heading, using GitHub's slugification rules.

External (``http(s)://``, ``mailto:``) links are out of scope — CI must
not flake on network state.
"""
from __future__ import annotations

import functools
import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) — ignores images' leading ! harmlessly (same rules)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$")
_CODE_FENCE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation (keep word
    chars and hyphens), spaces become hyphens."""
    h = heading.strip().lower()
    h = re.sub(r"`", "", h)
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


@functools.lru_cache(maxsize=None)
def anchors_of(path: str) -> set[str]:
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    in_fence = False
    for line in open(path, encoding="utf-8"):
        if _CODE_FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING.match(line)
        if not m:
            continue
        slug = github_slug(m.group(1))
        k = counts.get(slug, 0)
        counts[slug] = k + 1
        slugs.add(slug if k == 0 else f"{slug}-{k}")
    return slugs


def links_of(path: str):
    in_fence = False
    for ln, line in enumerate(open(path, encoding="utf-8"), 1):
        if _CODE_FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK.finditer(line):
            yield ln, m.group(1)


def check_file(path: str) -> tuple[list[str], int]:
    errors = []
    n_links = 0
    base = os.path.dirname(os.path.abspath(path))
    for ln, target in links_of(path):
        n_links += 1
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, ...
            continue
        frag = None
        if "#" in target:
            target, frag = target.split("#", 1)
        if target:
            dest = os.path.normpath(os.path.join(base, target))
            if not os.path.exists(dest):
                errors.append(f"{path}:{ln}: broken link target {target!r}")
                continue
        else:
            dest = path
        if frag is not None and dest.endswith(".md"):
            if frag not in anchors_of(dest):
                errors.append(f"{path}:{ln}: broken anchor "
                              f"{'#' + frag!r} in {os.path.relpath(dest, REPO)}")
    return errors, n_links


def main(argv=None) -> int:
    files = (argv or sys.argv[1:]) or (
        [os.path.join(REPO, "README.md"), os.path.join(REPO, "DESIGN.md")]
        + sorted(glob.glob(os.path.join(REPO, "docs", "*.md"))))
    errors = []
    n_links = 0
    for f in files:
        errs, n = check_file(f)
        errors.extend(errs)
        n_links += n
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files, {n_links} links, "
          f"{len(errors)} broken")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
