"""Execute the README's fenced ``python`` blocks (the docs CI gate).

    PYTHONPATH=src python docs/run_doctest.py [markdown files...]

Every ```` ```python ```` block is executed in its own namespace, in
order; any exception fails the run. This is what keeps the documented
quickstart from rotting: if the stable API drifts, this script — wired
into the CI docs job — goes red before a user does.
"""
from __future__ import annotations

import os
import re
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_BLOCK = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)


def blocks_of(path: str) -> list[str]:
    return [b.strip("\n") for b in _BLOCK.findall(open(path).read())]


def main(argv=None) -> int:
    files = (argv or sys.argv[1:]) or [os.path.join(REPO, "README.md")]
    failures = 0
    total = 0
    for path in files:
        for i, block in enumerate(blocks_of(path)):
            total += 1
            label = f"{os.path.relpath(path, REPO)}[block {i}]"
            t0 = time.perf_counter()
            try:
                exec(compile(block, label, "exec"), {"__name__": "__doc__"})
            except Exception:
                import traceback
                traceback.print_exc()
                print(f"FAIL {label}", file=sys.stderr)
                failures += 1
            else:
                print(f"ok   {label} ({time.perf_counter() - t0:.1f}s)")
    print(f"{total - failures}/{total} documented blocks executed cleanly")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
