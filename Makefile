# Developer entry points. `make test-fast` is the tier-1 iteration loop
# (seconds, -m fast subset); `make test` is the full suite (~minutes).
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench bench-full

test:
	$(PY) -m pytest -q --continue-on-collection-errors

test-fast:
	$(PY) -m pytest -q -m fast

bench:
	$(PY) -m benchmarks.run

bench-full:
	$(PY) -m benchmarks.run --full
