# Developer entry points. `make test-fast` is the tier-1 iteration loop
# (seconds, -m fast subset); `make test` is the full suite (~minutes);
# `make docs` regenerates the API reference, `make docs-check` runs the
# same gates CI does (doctest + links + api.md freshness).
#
# `make test` runs as four process-isolated shards (DESIGN.md §9): a
# monolithic run intermittently segfaults jaxlib on CPU once one
# interpreter has accumulated enough compiled XLA programs (observed
# near test_pallas_tree, and in test_stream once the kernel suites were
# split out), so the compile-heavy suites each get a fresh interpreter.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

# the Pallas interpret-mode shard: every module that drives the lane-
# tiled kernel (and its tuner/reorder conformance sweeps) in-process
PALLAS_TESTS := tests/test_pallas_tree.py tests/test_reorder.py \
	tests/test_tune.py
# the streaming/serving shard: the other compile-heavy suites (hundreds
# of jitted programs each) get their own interpreter too
STREAM_TESTS := tests/test_stream.py tests/test_serve.py \
	tests/test_serve_linearizability.py tests/test_system.py

.PHONY: test test-shard-core test-shard-pallas test-shard-stream \
	test-shard-faults test-fast test-faults bench bench-full bench-tune \
	docs docs-check

test: test-shard-core test-shard-pallas test-shard-stream \
	test-shard-faults

test-shard-core:
	$(PY) -m pytest -q --continue-on-collection-errors -m "not fault" \
		$(addprefix --ignore=,$(PALLAS_TESTS)) \
		$(addprefix --ignore=,$(STREAM_TESTS)) \
		--ignore=tests/test_durability.py

test-shard-pallas:
	$(PY) -m pytest -q $(PALLAS_TESTS)

test-shard-stream:
	$(PY) -m pytest -q -m "not fault" $(STREAM_TESTS)

test-shard-faults:
	$(PY) -m pytest -q tests/test_durability.py
	$(PY) -m pytest -q -m fault

test-fast:
	$(PY) -m pytest -q -m fast

test-faults:
	$(PY) -m pytest -q -m fault

bench:
	$(PY) -m benchmarks.run

bench-full:
	$(PY) -m benchmarks.run --full

# regenerate BENCH_traversal.json with the measured per-plan search on
# (REPRO_TUNE=search); fails if the pallas engine loses the end-to-end
# wall race (ratio > 1.0) on any scenario
bench-tune:
	$(PY) -m benchmarks.bench_phase_cost --tune

docs:
	$(PY) docs/gen_api.py

docs-check:
	$(PY) docs/run_doctest.py
	$(PY) docs/check_links.py
	$(PY) docs/gen_api.py --check
