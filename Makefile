# Developer entry points. `make test-fast` is the tier-1 iteration loop
# (seconds, -m fast subset); `make test` is the full suite (~minutes);
# `make docs` regenerates the API reference, `make docs-check` runs the
# same gates CI does (doctest + links + api.md freshness).
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-faults bench bench-full docs docs-check

test:
	$(PY) -m pytest -q --continue-on-collection-errors

test-fast:
	$(PY) -m pytest -q -m fast

test-faults:
	$(PY) -m pytest -q -m fault

bench:
	$(PY) -m benchmarks.run

bench-full:
	$(PY) -m benchmarks.run --full

docs:
	$(PY) docs/gen_api.py

docs-check:
	$(PY) docs/run_doctest.py
	$(PY) docs/check_links.py
	$(PY) docs/gen_api.py --check
